"""Quickstart: hybrid sparse attention in five minutes.

  PYTHONPATH=src python examples/quickstart.py

Builds the paper's Longformer pattern, runs all attention engines, verifies
they agree, and demonstrates the data scheduler (splitting + reordering).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (causal_sliding_window, dilated_window, longformer,
                        hybrid_attention, schedule, vil)

rng = np.random.default_rng(0)
B, H, N, D = 2, 4, 512, 64
q, k, v = (jnp.asarray(rng.normal(size=(B, H, N, D)), jnp.float32)
           for _ in range(3))

# 1. The paper's Longformer pattern: window 512... scaled down to n=512.
pat = longformer(window_size=64, n_global=2)
print(f"pattern: {pat}")
print(f"sparsity at n={N}: {pat.sparsity(N):.3f}")

# 2. Run it through each engine — identical results.
outs = {}
for impl in ("dense_ref", "blockwise", "pallas_interpret"):
    outs[impl] = hybrid_attention(q, k, v, pat, impl=impl,
                                  block_q=64, block_k=64)
for impl, out in outs.items():
    err = float(jnp.max(jnp.abs(out - outs["dense_ref"])))
    print(f"{impl:18s} max err vs oracle: {err:.2e}")

# 3. The data scheduler: what actually executes (paper §4).
sched = schedule(pat, N)
print(f"\nscheduler: bands={sched.bands} n_global={sched.n_global}")
est = sched.work_estimate(64, 64)
print(f"tile walk: {est['q_blocks']} q-blocks x "
      f"{est['kv_steps_per_q_block']} kv-steps, "
      f"utilization={est['utilization']:.2f}")

# 4. Dilated windows get *reordered* into sliding windows (paper §4.2).
dil = causal_sliding_window(16, dilation=4)
sd = schedule(dil, N)
print(f"\ndilated pattern reordered: perm[:8]={sd.perm[:8].tolist()}... "
      f"working band={sd.bands[0]}")
out_dil = hybrid_attention(q, k, v, dil)
ref_dil = hybrid_attention(q, k, v, dil, impl="dense_ref")
print(f"dilated blockwise vs oracle: "
      f"{float(jnp.max(jnp.abs(out_dil - ref_dil))):.2e}")

# 5. ViL 2-D windows lower to a union of bands...
pat2d = vil((16, 32), (5, 5), n_global=2)  # 16x32 grid + 2 global tokens
s2 = schedule(pat2d, pat2d.seq_len())
print(f"\nViL 2-D pattern -> {len(s2.bands)} bands: {s2.bands[:3]}...")

# 6. ...and the ExecutionPlan fuses all bands + the global column into ONE
#    deduplicated tile walk = one kernel launch (vs one launch per band).
plan = s2.plan(32, 32)
st = plan.stats()
print(f"ExecutionPlan: {st['launches']} launch, "
      f"{st['executed_tiles']} tiles "
      f"(per-band walk: {st['per_band_launches']} launches, "
      f"{st['per_band_tiles']} tiles -> "
      f"{st['per_band_tiles'] / st['executed_tiles']:.1f}x dedup)")
print("\nOK")
